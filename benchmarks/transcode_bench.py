"""Benchmark harness — one function per paper table/figure.

Methodology mirrors the paper §6.1: in-memory single-thread conversions,
repeated N times, minimum timing reported (after jit warmup), speeds in
**gigacharacters per second** (format-oblivious, §6.1).

CPU caveat: this container benchmarks the *algorithms* under XLA:CPU —
absolute numbers are not TPU numbers (the dry-run roofline covers the
TPU story); the *relative* ordering (vectorized vs scalar, fast paths vs
general) reproduces the paper's findings.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import baseline, transcode as tc
from repro.data import synthetic

LIPSUM_LANGS = ["arabic", "chinese", "emoji", "hebrew", "hindi",
                "japanese", "korean", "latin", "russian"]
N_CHARS = 1 << 17          # 128k chars per document: keeps the ASCII fast
                           # paths bandwidth-bound (not dispatch-bound), so
                           # the strategy ordering is stable run to run
REPS = 12


def _time_min(fn, reps=REPS):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _gcps(n_chars, secs):
    return n_chars / secs / 1e9


def _prep(lang, n=N_CHARS, seed=0):
    b = synthetic.utf8_array(lang, n, seed).astype(np.int32)
    u = synthetic.utf16_units(lang, n, seed).astype(np.int32)
    return jnp.asarray(b), jnp.asarray(u), len(b), len(u), n


def _prep_narrow(lang, n=N_CHARS, seed=0):
    """Narrow-dtype device buffers for the fused strategy (uint8/uint16):
    ingress HBM traffic is 1 byte per UTF-8 byte and 2 per UTF-16 unit."""
    b = synthetic.utf8_array(lang, n, seed)          # uint8
    u = synthetic.utf16_units(lang, n, seed)         # uint16
    return jnp.asarray(b), jnp.asarray(u)


# ---------------------------------------------------------------------------


def table5(langs=LIPSUM_LANGS, n_chars=N_CHARS):
    """Non-validating UTF-8 -> UTF-16 (paper Table 5).

    Every strategy gets the SAME device buffer: raw uint8 bytes, as the
    ingest pipeline ships them (DESIGN.md §2).  Strategies that compute
    in int32 pay their ingress widening inside the timed region — the
    narrow-dtype I/O of ``fused`` is part of what is being measured.
    """
    rows = []
    for lang in langs:
        nch = n_chars
        b8, _ = _prep_narrow(lang, n_chars)
        fns = {
            name: (jax.jit(lambda x, s=strat: tc.transcode(
                x, "utf16", src_format="utf8", strategy=s,
                validate=False)), b8)
            for name, strat in (("onepass", "onepass"), ("fused", "fused"),
                                ("blockparallel", "blockparallel"),
                                ("windowed(paper)", "windowed"))
        }
        row = {"lang": lang}
        for name, (f, x) in fns.items():
            jax.block_until_ready(f(x))  # warmup/compile
            t = _time_min(lambda f=f, x=x: jax.block_until_ready(f(x)))
            row[name] = _gcps(nch, t)
        rows.append(row)
    return rows


def table6(langs=LIPSUM_LANGS, n_chars=N_CHARS, with_scalar=True):
    """Validating UTF-8 -> UTF-16 (paper Table 6 / Fig. 5)."""
    rows = []
    for lang in langs:
        nch = n_chars
        b8, _ = _prep_narrow(lang, n_chars)
        raw = bytes(np.asarray(b8))
        fns = {
            name: (jax.jit(lambda x, s=strat: tc.transcode(
                x, "utf16", src_format="utf8", strategy=s,
                validate=True)), b8)
            for name, strat in (("onepass", "onepass"), ("fused", "fused"),
                                ("blockparallel", "blockparallel"),
                                ("windowed(paper)", "windowed"))
        }
        row = {"lang": lang}
        for name, (f, x) in fns.items():
            jax.block_until_ready(f(x))
            t = _time_min(lambda f=f, x=x: jax.block_until_ready(f(x)))
            row[name] = _gcps(nch, t)
        row["codecs(ICU-standin)"] = _gcps(nch, _time_min(
            lambda: baseline.python_codecs_utf8_to_utf16(raw)))
        if with_scalar:
            nb8 = np.asarray(b8)[: 4096]  # scalar DFA is slow
            nch8 = int(((nb8 & 0xC0) != 0x80).sum())
            row["finite(scalar)"] = _gcps(nch8, _time_min(
                lambda: baseline.hoehrmann_utf8_to_utf16(nb8), reps=3))
        rows.append(row)
    return rows


def table9(langs=LIPSUM_LANGS, n_chars=N_CHARS):
    """Validating UTF-16 -> UTF-8 (paper Table 9 / Fig. 6)."""
    rows = []
    for lang in langs:
        nch = n_chars
        _, u16 = _prep_narrow(lang, n_chars)
        raw16 = np.asarray(u16).tobytes()
        fns = {
            name: (jax.jit(lambda x, s=strat: tc.transcode(
                x, "utf8", src_format="utf16", strategy=s,
                validate=True)), u16)
            for name, strat in (("onepass", "onepass"), ("fused", "fused"),
                                ("blockparallel", "blockparallel"),
                                ("windowed(paper)", "windowed"))
        }
        row = {"lang": lang}
        for name, (f, x) in fns.items():
            jax.block_until_ready(f(x))
            t = _time_min(lambda f=f, x=x: jax.block_until_ready(f(x)))
            row[name] = _gcps(nch, t)
        row["codecs(ICU-standin)"] = _gcps(nch, _time_min(
            lambda: baseline.python_codecs_utf16_to_utf8(raw16)))
        rows.append(row)
    return rows


def table_replace(langs=("latin", "arabic", "emoji"), n_chars=N_CHARS,
                  corrupt_every=257):
    """Beyond-paper: malformed traffic under the ``errors=`` policy.

    Mutates the corpus (one corrupt byte every ``corrupt_every`` input
    bytes) and times the fused pipeline under errors="replace" — lossy
    U+FFFD ingestion at full speed — against errors="strict" on the same
    mutated buffer (which merely locates the first error) and against
    the strict path on the clean buffer (the no-error baseline).
    """
    rows = []
    for lang in langs:
        nch = n_chars
        b8, _ = _prep_narrow(lang, n_chars)
        bad = np.asarray(b8).copy()
        bad[::corrupt_every] = 0xFF
        bad8 = jnp.asarray(bad)
        fns = {
            "replace(mutated)": (jax.jit(lambda x: tc.transcode(
                x, "utf16", src_format="utf8", strategy="fused",
                errors="replace")), bad8),
            "strict(mutated)": (jax.jit(lambda x: tc.transcode(
                x, "utf16", src_format="utf8", strategy="fused",
                errors="strict")), bad8),
            "strict(clean)": (jax.jit(lambda x: tc.transcode(
                x, "utf16", src_format="utf8", strategy="fused",
                errors="strict")), b8),
        }
        row = {"lang": lang}
        for name, (f, x) in fns.items():
            jax.block_until_ready(f(x))
            t = _time_min(lambda f=f, x=x: jax.block_until_ready(f(x)))
            row[name] = _gcps(nch, t)
        rows.append(row)
    return rows


def table_ragged(batch_sizes=(8, 64), n_chars=2048, reps=6):
    """Beyond-paper: ragged packed batches vs padded vmap.

    A batch of B documents transcodes either as ONE grid launch over a
    tile-aligned packed stream (``onepass``: single-pass kernel, segment
    scan carried in SMEM; ``fused``: the two-launch count/cumsum/write
    reference — per-document bookkeeping is per-tile scalars either way,
    no padding tiles scanned) or as a ``vmap`` of the single-document
    pipeline over a padded [B, L] buffer (the reference): every document
    pays all of L.  Two length mixes per batch size: ``uniform`` (every
    document the same length — vmap's best case) and ``skewed`` (one
    long document per 8, the rest 1/8th of its length — the
    serving-traffic shape, where padding dominates the vmap cost).
    Speeds are total gigacharacters of the batch per second.
    """
    from repro.core import packing
    from repro.data import pipeline

    langs = ["latin", "arabic", "chinese", "emoji"]
    rows = []
    for b in batch_sizes:
        for skew, length_of in (
                ("uniform", lambda i: n_chars),
                ("skewed", lambda i: n_chars if i % 8 == 0
                 else max(n_chars // 8, 64))):
            docs = [synthetic.utf8_array(langs[i % 4], length_of(i), seed=i)
                    for i in range(b)]
            nch = sum(length_of(i) for i in range(b))

            pk = packing.pack_documents(docs)
            pdata, poffs, plens = (jnp.asarray(pk.data),
                                   jnp.asarray(pk.offsets),
                                   jnp.asarray(pk.lengths))

            cap = -(-max(len(d) for d in docs) // packing.TILE) \
                * packing.TILE
            padded = np.zeros((b, cap), np.uint8)
            for i, d in enumerate(docs):
                padded[i, : len(d)] = d
            vdocs = jnp.asarray(padded)
            vlens = jnp.asarray(np.asarray([len(d) for d in docs],
                                           np.int32))

            row = {"lang": f"b{b}/{skew}"}
            for strat in ("onepass", "fused"):
                packed_fn = jax.jit(
                    lambda d, o, l, s=strat: tc.ragged_transcode(
                        d, o, l, src_format="utf8", dst_format="utf16",
                        strategy=s))
                jax.block_until_ready(packed_fn(pdata, poffs, plens))
                row[strat] = _gcps(nch, _time_min(
                    lambda packed_fn=packed_fn: jax.block_until_ready(
                        packed_fn(pdata, poffs, plens)), reps=reps))
            vmap_fn = lambda: jax.block_until_ready(
                pipeline.batch_utf8_to_utf16(vdocs, vlens,
                                             strategy="vmap"))
            vmap_fn()  # warmup/compile
            row["vmap"] = _gcps(nch, _time_min(vmap_fn, reps=reps))
            rows.append(row)
    return rows


def table_ascii_runs(n_chars=N_CHARS, reps=REPS, spans=(0, 1, 8, 64)):
    """Beyond-paper: mostly-ASCII documents with occasional multibyte
    spans — the per-tile ASCII fast path's acceptance surface.

    A document of ``n_chars`` ASCII bytes gets ``k`` three-byte CJK
    spans scattered through it (one per contaminated VMEM tile).  With
    ``k = 0`` every strategy's whole-buffer ASCII cond short-circuits;
    with ``k >= 1`` the whole-buffer cond fails and the two-pass fused
    pipeline decodes EVERY tile twice, while the one-pass kernel's
    per-tile skip (DESIGN.md §9) still reduces each untouched tile to a
    widening copy.  Rows are ``ascii+k`` spans; speeds in Gchars/s.
    """
    rows = []
    for k in spans:
        base = np.full(n_chars, 0x61, np.uint8)   # 'a' * n_chars
        if k:
            # One span per contaminated tile, spread across the buffer.
            stride = max(n_chars // k, 1024)
            cjk = np.frombuffer("中".encode("utf-8"), np.uint8)
            for j in range(k):
                pos = min(j * stride + 17, n_chars - 3)
                base[pos: pos + 3] = cjk
        nch = n_chars - 2 * k          # each 3-byte char replaces 3 ASCII
        b8 = jnp.asarray(base)
        row = {"lang": f"ascii+{k}spans"}
        for strat in ("onepass", "fused", "blockparallel"):
            f = jax.jit(lambda x, s=strat: tc.transcode(
                x, "utf16", src_format="utf8", strategy=s))
            jax.block_until_ready(f(b8))
            row[strat] = _gcps(nch, _time_min(
                lambda f=f: jax.block_until_ready(f(b8)), reps=reps))
        rows.append(row)
    return rows


def table_matrix(n_chars=N_CHARS, lang="arabic", reps=REPS):
    """Beyond-paper: the full codec matrix, GC/s per format pair x strategy.

    Every supported (src, dst) cell of the decode×encode composition
    (DESIGN.md §8) is timed through the SAME generic fused driver and
    the pure-jnp block-parallel reference.  Source buffers are the
    narrow-dtype wire forms of one corpus (Latin-1 uses a high-byte
    corpus of its own, since the multilingual corpora do not fit in one
    byte per character).
    """
    text = synthetic.utf8_array(lang, n_chars, 0).tobytes().decode("utf-8")
    l1_rng = np.random.default_rng(0)
    l1_text = "".join(chr(c) for c in l1_rng.integers(0x20, 0x100, n_chars))
    rows = []
    for src, dst in tc.PAIRS:
        t = l1_text if "latin1" in (src, dst) else text
        nch = len(t)
        wire = {
            "utf8": lambda t: np.frombuffer(t.encode("utf-8"), np.uint8),
            "utf16": lambda t: np.frombuffer(t.encode("utf-16-le"),
                                             np.uint16),
            "utf32": lambda t: np.frombuffer(t.encode("utf-32-le"),
                                             np.uint32),
            "latin1": lambda t: np.frombuffer(t.encode("latin-1"),
                                              np.uint8),
        }[src](t)
        x = jnp.asarray(wire)
        row = {"lang": f"{src}->{dst}"}
        for strat in ("onepass", "fused", "blockparallel"):
            f = jax.jit(lambda v, s=src, d=dst, st=strat: tc.transcode(
                v, d, src_format=s, strategy=st))
            jax.block_until_ready(f(x))  # warmup/compile
            t_min = _time_min(lambda: jax.block_until_ready(f(x)),
                              reps=reps)
            row[strat] = _gcps(nch, t_min)
        rows.append(row)
    return rows


def table_stream(lang="arabic", n_chars=N_CHARS, chunk_sizes=(1024, 4096),
                 reps=REPS):
    """Beyond-paper: resumable streaming vs whole-buffer transcode.

    The headline (utf8, utf16) cell fed chunk-by-chunk through
    ``repro.core.stream`` (holdback + repeated single-pass launches,
    DESIGN.md §10) against the whole-buffer strategies on the same
    corpus — the cost of resumability is the per-chunk launch overhead,
    so smaller chunks sit further below the whole-buffer line.
    """
    from repro.core import stream as cs
    b = synthetic.utf8_array(lang, n_chars, 0)
    nch = len(b.tobytes().decode("utf-8"))
    x = jnp.asarray(b)
    whole = {}
    for strat in ("onepass", "fused", "blockparallel"):
        f = jax.jit(lambda v, st=strat: tc.transcode(
            v, "utf16", src_format="utf8", strategy=st))
        jax.block_until_ready(f(x))  # warmup/compile
        t_min = _time_min(lambda: jax.block_until_ready(f(x)), reps=reps)
        whole[strat] = _gcps(nch, t_min)
    rows = []
    for size in chunk_sizes:
        def run(size=size):
            st = cs.stream_init("utf8", "utf16")
            for i in range(0, len(b), size):
                _, st = cs.transcode_stream_chunk(st, b[i: i + size])
            cs.finalize(st)
        run()                        # warmup/compile the chunk shapes
        t_min = _time_min(run, reps=max(3, reps // 3))
        row = {"lang": f"{lang}@{size}", "stream": _gcps(nch, t_min)}
        row.update(whole)
        rows.append(row)
    return rows


def _serve_trace(n_requests, max_prompt, max_new, seed=11):
    """Seeded skewed heavy-traffic trace for the serve schedulers.

    Prompt lengths are skewed (every eighth request is long — exercises
    the admission buckets) and, INDEPENDENTLY, every fourth request
    wants the full generation budget while the rest want a couple of
    tokens.  Generation length is what admission-time bucketing cannot
    see: a wave whose slots drew one full-budget straggler idles its
    other slots for the whole tail, while continuous refill backfills
    them immediately — that per-wave straggler tax is the thing this
    trace measures.  ASCII-only prompts: the trace measures scheduling,
    not ingress validation (the transcode tables cover that).
    """
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        n = int(rng.integers(max_prompt // 2, max_prompt - 1)) \
            if i % 8 == 3 else int(rng.integers(4, 12))
        prompt = bytes(rng.integers(0x61, 0x7B, n, dtype=np.uint8))
        reqs.append(Request(prompt, max_new=max_new if i % 4 == 1 else 2))
    return reqs


def table_serve(n_requests=32, max_batch=4, max_prompt=64, max_new=64,
                reps=3):
    """Beyond-paper: continuous batching vs wave batching on the serve
    engine's skewed trace.

    The SAME model, ingress cells and per-bucket prefill geometry run
    under both schedulers — the only difference is the refill condition
    (a freed slot refills immediately vs once the whole wave drains).
    Rows: throughput in requests/s per scheduler (the gated cell) and
    submit->settle latency percentiles in ms (reported, not gated: the
    p50/p99 come from the last timed rep while rps is min-of-reps).
    """
    from repro.models import registry
    from repro.serve.engine import Engine
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    row_rps = {"lang": "rps"}
    row_lat = {"lang": "latency"}
    for sched in ("wave", "continuous"):
        e = Engine(model, cfg, fam, params, max_batch=max_batch,
                   max_prompt=max_prompt, max_new=max_new,
                   queue_limit=n_requests, scheduler=sched)
        trace = _serve_trace(n_requests, max_prompt, max_new)
        res = e.serve(trace)          # warmup: compiles every cell
        assert all(r.ok for r in res), [r.error for r in res if not r.ok]

        def run(e=e, trace=trace):
            e.latencies.clear()
            e.serve(trace)

        t = _time_min(run, reps=reps)
        lat_ms = np.asarray(sorted(e.latencies.values())) * 1e3
        row_rps[sched] = n_requests / t
        row_lat[f"{sched}_p50_ms"] = float(np.percentile(lat_ms, 50))
        row_lat[f"{sched}_p99_ms"] = float(np.percentile(lat_ms, 99))
    return [row_rps, row_lat]


# Executed in a child process: the bench process has already initialized
# jax with ONE device, and the device count is locked at first init, so
# the multi-shard sweep needs a fresh interpreter with the forced-8
# host-platform flag.  Prints one marker-prefixed JSON line on stdout.
_SHARD_BENCH_CODE = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# Interpret-mode kernels run as GIL-holding Python on the main thread;
# the default 5 ms thread switch interval would starve the staging
# thread of whole kernel-sized windows and charge pure scheduler latency
# to the feeder as stall.  A finer interval measures the pipeline, not
# the Python scheduler (real device kernels release the GIL, so this is
# a bench-subprocess concern only).
sys.setswitchinterval(0.0005)
import numpy as np
import jax

from repro.core import packing, shard, transcode as tc
from repro.data import shard_feed, synthetic
from repro.launch import mesh as lm

cfg = json.loads(sys.argv[1])
lang, n_chars = cfg["lang"], cfg["n_chars"]
waves, reps = cfg["waves"], cfg["reps"]

docs = [synthetic.utf8_array(lang, n_chars, seed=i)
        for i in range(cfg["n_docs"])]
pk = packing.pack_documents(docs)
nch = sum(len(bytes(d).decode("utf-8")) for d in docs)

# Single-device reference: the onepass ragged launch on the same batch.
ref_fn = lambda: tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                     src_format="utf8",
                                     dst_format="utf16")
jax.block_until_ready(ref_fn().buffer)       # warmup/compile
best = float("inf")
for _ in range(reps):
    t0 = time.perf_counter()
    jax.block_until_ready(ref_fn().buffer)
    best = min(best, time.perf_counter() - t0)
single_gcps = nch / best / 1e9

out = {"single": single_gcps, "sharded": {}, "hidden": {}}
for n in cfg["shard_counts"]:
    mesh = lm.make_transcode_mesh(n)
    plans = [shard.plan_shards(pk.data, pk.offsets, pk.lengths, n)
             for _ in range(waves)]
    shard_feed.run_sharded_waves(mesh, plans[:1], src="utf8",
                                 dst="utf16")  # warmup/compile
    best, best_stats = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        _outs, stats = shard_feed.run_sharded_waves(
            mesh, plans, src="utf8", dst="utf16")
        t = time.perf_counter() - t0
        if t < best:
            best, best_stats = t, stats
    out["sharded"][str(n)] = waves * nch / best / 1e9
    out["hidden"][str(n)] = shard_feed.hidden_fraction(best_stats)
print("TABLE_SHARD_JSON " + json.dumps(out))
"""


def table_shard(lang="arabic", n_chars=1 << 14, n_docs=8, waves=4,
                shard_counts=(1, 2, 4, 8), reps=3):
    """Beyond-paper: mesh-sharded ragged transcode vs the single-device
    onepass launch, with the double-buffered host->device feeder.

    Each ``lang@N`` row carries the sharded GC/s at N shards (gated
    against the ``single`` reference, see bench_gate TABLE_STRATEGIES);
    the ``transfer_hidden`` row carries the feeder's per-shard-count
    transfer-hidden fraction — the fraction of measured host->device
    staging time that overlapped kernel execution (>= 0.5 is the
    acceptance bar; on this interpret-mode CPU setup kernels dwarf the
    copies, so a healthy pipeline sits near 1.0).

    Runs in a forced-8-device subprocess: the parent bench process owns
    a single-device jax runtime, and the device count cannot change
    after init.
    """
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys

    cfg = {"lang": lang, "n_chars": n_chars, "n_docs": n_docs,
           "waves": waves, "shard_counts": list(shard_counts),
           "reps": reps}
    env = dict(_os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = _sp.run([_sys.executable, "-c", _SHARD_BENCH_CODE,
                 _json.dumps(cfg)],
                capture_output=True, text=True, env=env, timeout=1200)
    marker = "TABLE_SHARD_JSON "
    line = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith(marker)), None)
    if r.returncode != 0 or line is None:
        raise RuntimeError(
            f"table_shard subprocess failed (rc={r.returncode}):\n"
            f"{r.stdout[-1000:]}\n{r.stderr[-2000:]}")
    out = _json.loads(line[len(marker):])
    rows = []
    for n in shard_counts:
        rows.append({"lang": f"{lang}@{n}",
                     "sharded": out["sharded"][str(n)],
                     "single": out["single"]})
    hidden = {"lang": "transfer_hidden"}
    for n in shard_counts:
        hidden[f"hidden@{n}"] = out["hidden"][str(n)]
    rows.append(hidden)
    return rows


def table8_proxy(langs=("arabic", "latin", "chinese")):
    """Instructions-per-byte proxy (paper Table 8): jaxpr FLOPs/bytes per
    input byte for each strategy — the HLO-op analogue of instruction
    counts."""
    from repro import costmodel as CM
    rows = []
    for lang in langs:
        b, _, nb, _, nch = _prep(lang, 4096)
        for name, fn in [
            ("blockparallel", lambda x: tc.transcode(
                x, "utf16", src_format="utf8", strategy="blockparallel")),
            ("windowed(paper)", lambda x: tc.transcode(
                x, "utf16", src_format="utf8", strategy="windowed")),
        ]:
            cost = CM.fn_cost(fn, jax.ShapeDtypeStruct(b.shape, b.dtype))
            rows.append({"lang": lang, "impl": name,
                         "flops_per_byte": cost.flops / nb,
                         "bytes_per_byte": cost.bytes / nb})
    return rows


def fig7(lang="arabic", sizes=(64, 256, 1024, 4096, 16384, 65536)):
    """Input-size sweep (paper Fig. 7): speed vs prefix length."""
    rows = []
    full = synthetic.utf8_array(lang, 1 << 17, 0).astype(np.int32)
    f = jax.jit(lambda x: tc.transcode(x, "utf16", src_format="utf8",
                                       strategy="blockparallel",
                                       validate=True))
    for n in sizes:
        b = jnp.asarray(full[:n])
        nch = int(((np.asarray(b) & 0xC0) != 0x80).sum())
        jax.block_until_ready(f(b))
        t = _time_min(lambda: jax.block_until_ready(f(b)))
        rows.append({"bytes": n, "gchars_per_s": _gcps(nch, t)})
    return rows


def print_rows(title, rows):
    print(f"\n== {title} ==")
    keys = None
    for r in rows:
        if list(r.keys()) != keys:          # heterogeneous tables (table_serve)
            keys = list(r.keys())
            print(",".join(keys))
        print(",".join(f"{r[k]:.3g}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))
