"""Render the dry-run sweep results as the EXPERIMENTS.md roofline table.

    python benchmarks/report_roofline.py [--mesh 16x16] [--md]
"""

import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = []
    for f in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt(x, unit=""):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    for scale, suf in [(1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")]:
        if abs(x) >= scale:
            return f"{x/scale:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def main():
    global OUT
    mesh = "16x16"
    for i, a in enumerate(sys.argv):
        if a == "--mesh":
            mesh = sys.argv[i + 1]
        if a == "--dir":
            OUT = sys.argv[i + 1]
    recs = [r for r in load() if r.get("mesh") == mesh or r.get("skipped")]
    seen = set()
    print(f"| arch | shape | FLOPs | bytes | coll B | t_comp | t_mem | "
          f"t_coll | bound | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    order = {}
    for r in recs:
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        order.setdefault(r["arch"], {})[r["shape"]] = r
    for arch in order:
        for shape in SHAPE_ORDER:
            r = order[arch].get(shape)
            if r is None:
                continue
            if r.get("skipped"):
                print(f"| {arch} | {shape} | SKIP | | | | | | "
                      f"{r['reason']} | |")
                continue
            print(f"| {arch} | {shape} | {fmt(r['hlo_flops'])} | "
                  f"{fmt(r['hlo_bytes'])} | {fmt(r['coll_bytes'])} | "
                  f"{r['t_compute_s']:.3f}s | {r['t_memory_s']:.3f}s | "
                  f"{r['t_collective_s']:.3f}s | {r['bottleneck']} | "
                  f"{r['useful_ratio'] and round(r['useful_ratio'], 2)} |")


if __name__ == "__main__":
    main()
