"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

Runs every paper-table analogue (Tables 5/6/9, Table 8 proxy, Fig. 7)
plus the ingest-pipeline microbench, printing CSV blocks.  Pass --quick
for a reduced sweep (CI).
"""

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import transcode_bench as tb

    langs = ["arabic", "chinese", "emoji", "latin"] if quick \
        else tb.LIPSUM_LANGS
    n = 1 << 13 if quick else tb.N_CHARS

    tb.print_rows("Table 5: non-validating UTF-8 -> UTF-16 (Gchars/s)",
                  tb.table5(langs, n))
    tb.print_rows("Table 6: validating UTF-8 -> UTF-16 (Gchars/s)",
                  tb.table6(langs, n, with_scalar=not quick))
    tb.print_rows("Table 9: validating UTF-16 -> UTF-8 (Gchars/s)",
                  tb.table9(langs, n))
    tb.print_rows("Table 8 proxy: ops per input byte",
                  tb.table8_proxy())
    tb.print_rows("Fig 7: input-size sweep (arabic)",
                  tb.fig7(sizes=(64, 1024, 16384) if quick
                          else (64, 256, 1024, 4096, 16384, 65536)))

    from benchmarks import pipeline_bench as pb
    tb.print_rows("Pipeline: device ingest throughput", pb.ingest_bench(
        n_chars=1 << 12 if quick else 1 << 15))


if __name__ == "__main__":
    main()
