"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

Runs every paper-table analogue (Tables 5/6/9, Table 8 proxy, Fig. 7)
plus the ingest-pipeline microbench, printing CSV blocks and writing a
machine-readable ``BENCH_transcode.json`` (strategy x language x Gchars/s
for every table) so the perf trajectory is tracked across PRs.

Flags:
  --quick   reduced sweep (CI)
  --smoke   2-language micro sweep of Tables 5/6/9 only (kernel-regression
            gate for scripts/check.sh; still writes the JSON)
  --out P   JSON output path (default: BENCH_transcode.json in the cwd)
"""

import json
import sys

# Bench-report schema version (see scripts/bench_gate.py): bumped when a
# run starts emitting tables an older committed baseline cannot know
# about, so the gate warns-and-skips unshared tables across schema
# versions instead of failing on them.  v2 added ``table_matrix``; v3
# added ``table_ascii_runs`` and the ``onepass`` strategy column to the
# existing sweeps (new strategies in a shared table are additive — the
# gate only compares its gated strategy — but the new table needs the
# version bump for the cross-version warn-and-skip rule); v4 added
# ``table_stream`` (chunked resumable streaming vs whole-buffer); v5
# added ``table_serve`` (continuous vs wave scheduling on the serve
# engine — its "strategy" keys are schedulers and its rps row is in
# requests/s, not Gchars/s); v6 marks the baseline regenerated under
# the cross-strategy gate pairs on tables 5/6/9 (onepass gated against
# blockparallel — and against fused on table 6 — see
# scripts/bench_gate.py TABLE_STRATEGIES): the pairs make the gate's
# relative mode compare ratios an older report also contains, and any
# table unique to one side of a v5/v6 comparison warns-and-skips as
# before; v7 added ``table_shard`` (mesh-sharded ragged transcode vs the
# single-device onepass reference, plus the feeder's transfer-hidden
# fraction rows — the ``hidden@N`` keys are fractions in [0, 1], not
# Gchars/s, and are asserted by scripts/check.sh rather than gated).
SCHEMA = 7


def _records(table: str, rows):
    """Flatten a strategy-keyed CSV row block into one record per cell."""
    out = []
    for row in rows:
        lang = row.get("lang")
        for key, val in row.items():
            if key == "lang" or not isinstance(val, float):
                continue
            out.append({"table": table, "lang": lang, "strategy": key,
                        "gchars_per_s": val})
    return out


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    smoke = "--smoke" in argv
    out_path = "BENCH_transcode.json"
    if "--out" in argv:
        i = argv.index("--out")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("error: --out requires a path argument")
        out_path = argv[i + 1]

    from benchmarks import transcode_bench as tb

    if smoke:
        # Two languages at full buffer size: at small sizes the ASCII
        # fast paths are dispatch-overhead-bound and the strategy
        # ordering is timer noise.
        langs, n = ["latin", "arabic"], tb.N_CHARS
    elif quick:
        langs, n = ["arabic", "chinese", "emoji", "latin"], 1 << 13
    else:
        langs, n = tb.LIPSUM_LANGS, tb.N_CHARS

    report = {"schema": SCHEMA, "langs": langs, "n_chars": n,
              "mode": "smoke" if smoke else ("quick" if quick else "full"),
              "records": []}

    t5 = tb.table5(langs, n)
    tb.print_rows("Table 5: non-validating UTF-8 -> UTF-16 (Gchars/s)", t5)
    report["records"] += _records("table5", t5)

    t6 = tb.table6(langs, n, with_scalar=not (quick or smoke))
    tb.print_rows("Table 6: validating UTF-8 -> UTF-16 (Gchars/s)", t6)
    report["records"] += _records("table6", t6)

    t9 = tb.table9(langs, n)
    tb.print_rows("Table 9: validating UTF-16 -> UTF-8 (Gchars/s)", t9)
    report["records"] += _records("table9", t9)

    # The codec matrix rides in every mode (incl. --smoke: it is the
    # acceptance surface for the decode×encode stage composition AND for
    # the one-pass pipeline — the utf8->utf16 row is the headline cell
    # where onepass must beat the two-pass fused baseline).
    # 16k chars / reps=10 even in the reduced modes: the micro-sized
    # cells otherwise sit in the ~0.5 ms regime where shared-machine
    # noise swamps both the onepass-vs-fused ordering (~10-15%) and the
    # fused/blockparallel ratio the CI gate tracks.
    tm = tb.table_matrix(n_chars=1 << 14 if (quick or smoke) else n,
                         reps=10 if (quick or smoke) else tb.REPS)
    tb.print_rows("Codec matrix: all format pairs (Gchars/s)", tm)
    report["records"] += _records("table_matrix", tm)

    # Mostly-ASCII documents with occasional multibyte spans: the
    # per-tile ASCII skip's acceptance surface (rides in every mode;
    # 64k chars keeps the ASCII fast paths out of the noise floor).
    ta = tb.table_ascii_runs(n_chars=1 << 16 if (quick or smoke) else n,
                             reps=8 if (quick or smoke) else tb.REPS,
                             spans=(0, 4) if (quick or smoke)
                             else (0, 1, 8, 64))
    tb.print_rows("ASCII runs: mostly-ASCII with multibyte spans "
                  "(Gchars/s)", ta)
    report["records"] += _records("table_ascii_runs", ta)

    # Streaming vs whole-buffer (rides in every mode incl. --smoke: the
    # resumable path is an acceptance surface now — a regression in the
    # per-chunk launch overhead shows up here first).  Capped at 32k
    # chars even in full mode: the chunked run is launch-bound and
    # scales linearly, while interpret-mode launches make the full-size
    # sweep needlessly slow.
    ts = tb.table_stream(n_chars=1 << 13 if (quick or smoke) else 1 << 15,
                         chunk_sizes=(1024, 4096),
                         reps=6 if (quick or smoke) else tb.REPS)
    tb.print_rows("Streaming: chunked resumable vs whole-buffer "
                  "UTF-8 -> UTF-16 (Gchars/s)", ts)
    report["records"] += _records("table_stream", ts)

    # Serve schedulers (rides in every mode incl. --smoke: the
    # continuous-beats-wave claim on the skewed trace is an acceptance
    # surface, gated per the TABLE_STRATEGIES map in bench_gate).  The
    # rps row is requests/s; the latency row's *_p50_ms/*_p99_ms keys
    # are submit->settle percentiles in ms, reported but not gated.
    tsv = tb.table_serve(n_requests=24 if (quick or smoke) else 32,
                         reps=2 if (quick or smoke) else 3)
    tb.print_rows("Serve: continuous vs wave scheduling (req/s, ms)", tsv)
    report["records"] += _records("table_serve", tsv)

    # Mesh-sharded ragged transcode + double-buffered feeder (rides in
    # every mode incl. --smoke: the sharded path's GC/s vs the
    # single-device onepass reference is gated, and the transfer-hidden
    # fraction is an acceptance surface for the feeder).  Runs in its
    # own forced-8-device subprocess, so the sizes stay modest even in
    # full mode — the sweep is launch-bound under interpret-mode Pallas.
    # 16k chars/doc even in the reduced modes: smaller waves put the
    # kernel windows at the Python thread-switch granularity, where the
    # feeder's stall measurement reads scheduler noise, not transfers.
    tsh = tb.table_shard(waves=3 if (quick or smoke) else 4,
                         reps=2 if (quick or smoke) else 3)
    tb.print_rows("Sharded: mesh-sharded ragged vs single-device "
                  "(Gchars/s; transfer_hidden row is a fraction)", tsh)
    report["records"] += _records("table_shard", tsh)

    if not smoke:
        tr = tb.table_replace(n_chars=n)
        tb.print_rows("Replace policy: mutated-corpus UTF-8 -> UTF-16 "
                      "(Gchars/s)", tr)
        report["records"] += _records("table_replace", tr)

        trg = tb.table_ragged(batch_sizes=(8, 64),
                              n_chars=1 << 10 if quick else 1 << 11)
        tb.print_rows("Ragged batch: packed vs padded-vmap UTF-8 -> UTF-16 "
                      "(Gchars/s, batch x skew)", trg)
        report["records"] += _records("table_ragged", trg)

        tb.print_rows("Table 8 proxy: ops per input byte", tb.table8_proxy())
        fig7 = tb.fig7(sizes=(64, 1024, 16384) if quick
                       else (64, 256, 1024, 4096, 16384, 65536))
        tb.print_rows("Fig 7: input-size sweep (arabic)", fig7)

        from benchmarks import pipeline_bench as pb
        tb.print_rows("Pipeline: device ingest throughput", pb.ingest_bench(
            n_chars=1 << 12 if quick else 1 << 15))

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nwrote {out_path} ({len(report['records'])} records)")


if __name__ == "__main__":
    main()
