"""Attribute collective traffic to model ops via HLO metadata.

    PYTHONPATH=src python benchmarks/diagnose_collectives.py \
        --arch h2o-danube-1.8b --shape train_4k [--multipod]

Prints per-op_name collective bytes (trip-count adjusted, per device) —
the §Perf loop's profiler.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
from collections import defaultdict

import numpy as np

import jax

from repro import roofline as RL
from repro.launch import dryrun as DR
from repro.launch import mesh as meshmod

_META_RE = re.compile(r'op_name="([^"]*)"')


def attribute(hlo_text, top=25):
    comps, entry = RL._parse_computations(hlo_text)
    trip_of = {}
    for name, lines in comps.items():
        for s in lines:
            wm = RL._WHILE_RE.search(s)
            if wm:
                tm = RL._TRIP_RE.search(s)
                trip_of[wm.group(2)] = int(tm.group(1)) if tm else 1

    # propagate nesting: body inside body
    def full_trip(name, seen=frozenset()):
        t = trip_of.get(name, 1)
        return t

    agg = defaultdict(float)
    cnt = defaultdict(int)
    for name, lines in comps.items():
        mult = trip_of.get(name, 1)
        for s in lines:
            for kind in RL._COLLECTIVES:
                if f" {kind}(" in s or f" {kind}-start(" in s:
                    eq = s.find(" = ")
                    op_pos = s.find(f" {kind}")
                    if eq < 0:
                        continue
                    b = RL._shape_bytes(s[eq + 3: op_pos])
                    m = _META_RE.search(s)
                    op = m.group(1) if m else "?"
                    # shorten: keep the jax-level op path tail
                    op = "/".join(op.split("/")[-4:])
                    agg[f"{kind} :: {op}"] += b * mult
                    cnt[f"{kind} :: {op}"] += mult
                    break
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:top]
    total = sum(agg.values())
    print(f"total per-device collective bytes: {total/1e9:.2f} GB")
    for k, v in rows:
        print(f"  {v/1e9:9.3f} GB  x{cnt[k]:<6d} {k}")
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    mesh = meshmod.make_production_mesh(multi_pod=args.multipod)
    fn, fargs, shardings, _ = DR.build_cell(
        args.arch, args.shape, mesh, remat=not args.no_remat)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings).lower(*fargs).compile()
    attribute(compiled.as_text())


if __name__ == "__main__":
    main()
